//! PJRT execution engine: loads `artifacts/*.hlo.txt`, compiles each
//! (model, batch) variant once on the CPU PJRT client, and executes them
//! from the Layer-3 serving hot path.  Python is never involved here.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* -> HloModuleProto
//! (text parser reassigns the 64-bit ids jax >= 0.5 emits) ->
//! XlaComputation -> PjRtLoadedExecutable.

use super::manifest::{Golden, Manifest, ModelArtifact, Variant};
use crate::util::error::{anyhow, bail, Context, Result};
use std::collections::HashMap;

// The native PJRT bindings are unavailable offline; `xla_stub` mirrors the
// exact API surface used below.  To run real numerics, replace this alias
// with the `xla` crate (see DESIGN.md §PJRT runtime).
use crate::runtime::xla_stub as xla;

use std::time::Instant;

/// A compiled (model, batch) executable plus its I/O signature.
pub struct LoadedVariant {
    pub model: String,
    pub variant: Variant,
    exe: xla::PjRtLoadedExecutable,
    /// cumulative wall-clock statistics (real CPU compute, reported
    /// separately from the simulator's virtual-time numbers)
    pub exec_count: std::cell::Cell<u64>,
    pub exec_secs: std::cell::Cell<f64>,
}

impl LoadedVariant {
    /// Execute on a full input buffer of exactly `input_len()` f32 elements.
    pub fn execute(&self, input: &[f32]) -> Result<Vec<f32>> {
        let want = self.variant.input_len();
        if input.len() != want {
            bail!(
                "{}/b{}: input has {} elems, executable wants {want}",
                self.model,
                self.variant.batch,
                input.len()
            );
        }
        let t0 = Instant::now();
        let dims: Vec<i64> = self.variant.input_shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input)
            .reshape(&dims)
            .context("reshaping input literal")?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .context("PJRT execute")?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().context("unwrapping result tuple")?;
        let values = out.to_vec::<f32>().context("reading result values")?;
        self.exec_count.set(self.exec_count.get() + 1);
        self.exec_secs
            .set(self.exec_secs.get() + t0.elapsed().as_secs_f64());
        Ok(values)
    }

    /// Execute `n <= batch` requests, padding the tail of the batch with
    /// zeros and truncating the output back to `n` requests.
    pub fn execute_padded(&self, input: &[f32], n: usize) -> Result<Vec<f32>> {
        let b = self.variant.batch;
        if n == 0 || n > b {
            bail!("{}/b{b}: cannot run {n} requests", self.model);
        }
        let per_in = self.variant.input_len() / b;
        if input.len() != n * per_in {
            bail!(
                "{}/b{b}: {n} requests need {} elems, got {}",
                self.model,
                n * per_in,
                input.len()
            );
        }
        let mut full = vec![0f32; self.variant.input_len()];
        full[..input.len()].copy_from_slice(input);
        let out = self.execute(&full)?;
        let per_out = self.variant.output_len() / b;
        Ok(out[..n * per_out].to_vec())
    }

    pub fn mean_exec_secs(&self) -> f64 {
        let n = self.exec_count.get();
        if n == 0 {
            f64::NAN
        } else {
            self.exec_secs.get() / n as f64
        }
    }
}

/// The engine owns the PJRT client and all compiled variants.
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    manifest: Manifest,
    variants: HashMap<(String, usize), LoadedVariant>,
    pub compile_secs: f64,
}

impl Engine {
    /// Create a CPU PJRT client without loading anything.
    pub fn new(manifest: Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            variants: HashMap::new(),
            compile_secs: 0.0,
        })
    }

    /// Load and compile every variant in the manifest (or a model subset).
    pub fn load_all(&mut self, only_models: Option<&[&str]>) -> Result<()> {
        let models: Vec<ModelArtifact> = self
            .manifest
            .models
            .iter()
            .filter(|m| only_models.map_or(true, |set| set.contains(&m.name.as_str())))
            .cloned()
            .collect();
        for m in &models {
            for v in &m.variants {
                self.load_variant(&m.name, v.batch)?;
            }
        }
        Ok(())
    }

    /// Load and compile a single (model, batch) variant; idempotent.
    pub fn load_variant(&mut self, model: &str, batch: usize) -> Result<()> {
        let key = (model.to_string(), batch);
        if self.variants.contains_key(&key) {
            return Ok(());
        }
        let art = self
            .manifest
            .model(model)
            .ok_or_else(|| anyhow!("model {model} not in manifest"))?;
        let variant = art
            .variants
            .iter()
            .find(|v| v.batch == batch)
            .ok_or_else(|| anyhow!("model {model} has no batch-{batch} variant"))?
            .clone();
        let path = self.manifest.dir.join(&variant.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow!("non-utf8 artifact path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", variant.file))?;
        self.compile_secs += t0.elapsed().as_secs_f64();
        self.variants.insert(
            key,
            LoadedVariant {
                model: model.to_string(),
                variant,
                exe,
                exec_count: std::cell::Cell::new(0),
                exec_secs: std::cell::Cell::new(0.0),
            },
        );
        Ok(())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn variant(&self, model: &str, batch: usize) -> Option<&LoadedVariant> {
        self.variants.get(&(model.to_string(), batch))
    }

    /// The loaded variant the dynamic batcher should use for `n` queued
    /// requests: smallest loaded batch >= n, else largest loaded.
    pub fn variant_for(&self, model: &str, n: usize) -> Option<&LoadedVariant> {
        let mut cands: Vec<&LoadedVariant> = self
            .variants
            .values()
            .filter(|v| v.model == model)
            .collect();
        cands.sort_by_key(|v| v.variant.batch);
        cands
            .iter()
            .find(|v| v.variant.batch >= n)
            .copied()
            .or_else(|| cands.last().copied())
    }

    pub fn loaded_count(&self) -> usize {
        self.variants.len()
    }

    /// Verify a model's numerics against its Python-produced golden pair.
    /// Returns the max absolute element error.
    pub fn verify_golden(&mut self, model: &str, tol: f32) -> Result<f32> {
        let art = self
            .manifest
            .model(model)
            .ok_or_else(|| anyhow!("model {model} not in manifest"))?
            .clone();
        let gfile = art
            .golden
            .as_ref()
            .ok_or_else(|| anyhow!("model {model} has no golden file"))?;
        let golden = Golden::load(&self.manifest.dir, gfile)?;
        self.load_variant(model, golden.batch)?;
        let v = self.variant(model, golden.batch).unwrap();
        let out = v.execute(&golden.input)?;
        if out.len() != golden.output.len() {
            bail!(
                "{model}: output len {} != golden {}",
                out.len(),
                golden.output.len()
            );
        }
        let mut max_err = 0f32;
        for (a, b) in out.iter().zip(golden.output.iter()) {
            max_err = max_err.max((a - b).abs());
        }
        if max_err > tol {
            bail!("{model}: golden mismatch, max |err| = {max_err} > tol {tol}");
        }
        Ok(max_err)
    }
}
