//! Determinism invariants: the DES event queue must break timestamp ties
//! in FIFO `seq` order (so identical runs replay identically), and the
//! profiler must produce bit-identical coefficients for the same seed —
//! the property every "deterministic per seed" experiment relies on.

use igniter::gpu::GpuKind;
use igniter::sim::EventQueue;

#[test]
fn same_timestamp_events_pop_in_fifo_seq_order() {
    // Schedule interleaved timestamps with many ties; the tie groups must
    // come back exactly in insertion order.
    let mut q = EventQueue::new();
    let mut expected: Vec<(u64, usize)> = Vec::new(); // (time-key, insertion#)
    let times = [5.0, 1.0, 5.0, 3.0, 1.0, 5.0, 3.0, 1.0, 1.0, 5.0];
    for (i, &t) in times.iter().enumerate() {
        q.schedule_at(t, i);
        expected.push((t as u64, i));
    }
    expected.sort_by_key(|&(t, i)| (t, i)); // stable FIFO within equal times

    let mut popped = Vec::new();
    while let Some((t, i)) = q.pop() {
        popped.push((t as u64, i));
    }
    assert_eq!(popped, expected);
}

#[test]
fn fifo_order_survives_incremental_scheduling() {
    // Ties created *while* draining (events scheduled at the current
    // timestamp) also obey FIFO among themselves.
    let mut q = EventQueue::new();
    q.schedule_at(10.0, 0);
    let (now, first) = q.pop().unwrap();
    assert_eq!((now, first), (10.0, 0));
    for i in 1..=4 {
        q.schedule_at(10.0, i);
    }
    let rest: Vec<usize> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
    assert_eq!(rest, vec![1, 2, 3, 4]);
}

#[test]
fn serving_pipeline_bit_identical_per_seed() {
    // Identical seeds must replay bit-identically through the decomposed
    // router/batcher/monitor pipeline — exercised on a multi-replica plan
    // so the routing path itself is covered.
    use igniter::coordinator::{ClusterSim, Policy};
    use igniter::gpu::Model;
    use igniter::provisioner::{self, ProfiledSystem, WorkloadSpec};
    use igniter::workload::ArrivalKind;

    let (hw, wls) = igniter::profiler::profile_all(GpuKind::V100, 42);
    let sys = ProfiledSystem {
        hw,
        coeffs: igniter::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
    };
    // a rate just beyond one gpulet forces a replica split
    let rate =
        igniter::provisioner::igniter::over_capacity_rate(&sys, Model::ResNet50, 40.0, 400.0);
    let specs = vec![WorkloadSpec::new(0, Model::ResNet50, 40.0, rate)];
    let plan = provisioner::provision(&sys, &specs);
    assert!(plan.replica_count(0) >= 2, "{plan:?}");

    let run = |seed: u64| {
        let mut sim = ClusterSim::new(
            GpuKind::V100,
            &plan,
            &specs,
            Policy::IgniterShadow,
            ArrivalKind::Poisson,
            seed,
            &[],
        );
        sim.set_horizon(6_000.0, 500.0);
        sim.run()
            .iter()
            .map(|s| {
                (
                    s.served,
                    s.arrivals,
                    s.p99_ms.to_bits(),
                    s.mean_ms.to_bits(),
                    s.replica_served.clone(),
                )
            })
            .collect::<Vec<_>>()
    };
    assert_eq!(run(9), run(9), "same seed drifted");
    assert_ne!(run(9), run(10), "seed has no effect on serving");
}

#[test]
fn closed_loop_autoscale_bit_identical_per_seed() {
    // The full closed loop — traced arrivals -> rate estimator -> online
    // re-plan -> shadow-instance migration -> drain/retire — must replay
    // bit-identically for a fixed seed: every stage is a pure function of
    // the seed and the event order.
    use igniter::coordinator::{ClusterSim, Policy, Reprovisioner};
    use igniter::provisioner::{self, ProfiledSystem, WorkloadSpec};
    use igniter::workload::trace::{RateTrace, TraceKind};
    use igniter::workload::{table1_workloads, ArrivalKind};

    let (hw, wls) = igniter::profiler::profile_all(GpuKind::V100, 42);
    let sys = ProfiledSystem {
        hw,
        coeffs: igniter::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
    };
    let specs = table1_workloads();
    let provisioned: Vec<WorkloadSpec> = specs
        .iter()
        .map(|s| {
            let mut c = s.clone();
            c.rate_rps = (s.rate_rps * 0.5).max(1.0);
            c
        })
        .collect();
    let plan = provisioner::provision(&sys, &provisioned);

    let run = |seed: u64| {
        let trace = RateTrace::generate(
            TraceKind::Spiky { base: 0.4, p: 0.35 },
            6,
            specs.len(),
            seed,
        );
        let mut sim = ClusterSim::new(
            GpuKind::V100,
            &plan,
            &specs,
            Policy::Static,
            ArrivalKind::Poisson,
            seed,
            &[],
        );
        sim.set_serving_policy(Box::new(Reprovisioner::new(
            sys.clone(),
            provisioned.clone(),
            plan.clone(),
        )));
        sim.set_rate_trace(&trace, 2_500.0);
        sim.set_horizon(15_000.0, 1_000.0);
        let stats = sim.run();
        let fingerprint: Vec<_> = stats
            .iter()
            .map(|s| {
                (
                    s.served,
                    s.arrivals,
                    s.still_queued,
                    s.p99_ms.to_bits(),
                    s.mean_ms.to_bits(),
                    s.final_resources.to_bits(),
                    s.replica_served.clone(),
                )
            })
            .collect();
        (fingerprint, sim.migrations(), sim.gpu_seconds().to_bits())
    };
    let a = run(21);
    assert_eq!(a, run(21), "closed loop drifted for the same seed");
    assert_ne!(a, run(22), "seed has no effect on the closed loop");
}

#[test]
fn calibrated_closed_loop_bit_identical_per_seed_and_inert_at_zero_observations() {
    // Two guards for the performance-model layer:
    //
    // 1. enabling calibration on a run whose model never diverges from
    //    the serving observations... is NOT this test — calibration DOES
    //    absorb observations here, so instead we require the calibrated
    //    closed loop (RLS state and all) to replay bit-identically per
    //    seed;
    // 2. a Reprovisioner with calibration *off* must produce exactly the
    //    same serving outcome as before this layer existed — the model
    //    threading alone moves nothing (checked against a second
    //    construction to make the comparison meaningful).
    use igniter::coordinator::{ClusterSim, Policy, Reprovisioner};
    use igniter::provisioner;
    use igniter::workload::{table1_workloads, ArrivalKind};

    let sys = igniter::profiler::profile_system(GpuKind::V100, 42);
    let specs = table1_workloads();
    let plan = provisioner::provision(&sys, &specs);

    let run = |seed: u64, calibrate: bool| {
        let mut sim = ClusterSim::new(
            GpuKind::V100,
            &plan,
            &specs,
            Policy::Static,
            ArrivalKind::Poisson,
            seed,
            &[],
        );
        let mut rp = Reprovisioner::new(sys.clone(), specs.clone(), plan.clone());
        if calibrate {
            rp = rp.with_calibration();
        }
        sim.set_serving_policy(Box::new(rp));
        sim.set_horizon(10_000.0, 1_000.0);
        let stats = sim.run();
        let fp: Vec<_> = stats
            .iter()
            .map(|s| {
                (
                    s.served,
                    s.arrivals,
                    s.still_queued,
                    s.p99_ms.to_bits(),
                    s.final_resources.to_bits(),
                )
            })
            .collect();
        (fp, sim.migrations(), sim.gpu_seconds().to_bits())
    };
    // calibrated runs replay bit-identically
    assert_eq!(run(5, true), run(5, true), "calibrated loop drifted");
    // with calibration off, two fresh constructions agree exactly
    assert_eq!(run(5, false), run(5, false));
}

#[test]
fn empty_fault_plan_is_a_bitwise_no_op() {
    // The chaos layer's disabled lane: installing `FaultPlan::none()`
    // (exactly what `FaultSpace::OFF` generates) must leave the closed
    // loop bit-identical to a sim that never heard of faults — zero
    // extra events, zero extra sequence numbers, identical float paths.
    // Full resilience flags with no fault state must be equally inert:
    // breakers only *observe* until something actually degrades.
    use igniter::coordinator::{ClusterSim, Policy, Reprovisioner, Resilience};
    use igniter::provisioner;
    use igniter::sim::faults::FaultPlan;
    use igniter::workload::{table1_workloads, ArrivalKind};

    let sys = igniter::profiler::profile_system(GpuKind::V100, 42);
    let specs = table1_workloads();
    let plan = provisioner::provision(&sys, &specs);
    let run = |with_plan: bool, resilience: bool| {
        let mut sim = ClusterSim::new(
            GpuKind::V100,
            &plan,
            &specs,
            Policy::Static,
            ArrivalKind::Poisson,
            17,
            &[],
        );
        let mut rp = Reprovisioner::new(sys.clone(), specs.clone(), plan.clone());
        if resilience {
            rp = rp.with_resilience(Resilience::ALL);
        }
        sim.set_serving_policy(Box::new(rp));
        if with_plan {
            sim.set_fault_plan(FaultPlan::none());
        }
        sim.set_horizon(10_000.0, 1_000.0);
        let stats = sim.run();
        let fp: Vec<_> = stats
            .iter()
            .map(|s| {
                (
                    s.served,
                    s.arrivals,
                    s.still_queued,
                    s.dropped,
                    s.p99_ms.to_bits(),
                    s.mean_ms.to_bits(),
                    s.final_resources.to_bits(),
                    s.replica_served.clone(),
                )
            })
            .collect();
        (
            fp,
            sim.migrations(),
            sim.gpu_seconds().to_bits(),
            sim.faults_injected(),
        )
    };
    let base = run(false, false);
    assert_eq!(base.3, 0);
    assert_eq!(base, run(true, false), "empty fault plan perturbed serving");
    assert_eq!(
        base,
        run(true, true),
        "resilience flags perturbed fault-free serving"
    );
}

#[test]
fn profiler_is_bit_identical_per_seed() {
    // Two independent profiling passes with the same seed must agree on
    // every fitted coefficient exactly (PartialEq on f64 = bitwise here,
    // no tolerance).
    let (hw_a, wls_a) = igniter::profiler::profile_all(GpuKind::V100, 42);
    let (hw_b, wls_b) = igniter::profiler::profile_all(GpuKind::V100, 42);
    assert_eq!(hw_a, hw_b);
    assert_eq!(wls_a.len(), wls_b.len());
    for (a, b) in wls_a.iter().zip(wls_b.iter()) {
        assert_eq!(a, b, "workload {} coefficients drifted between runs", a.name);
    }

    // ...and a different seed must actually change the measurements
    // (guards against the profiler silently ignoring its seed).
    let (_, wls_c) = igniter::profiler::profile_all(GpuKind::V100, 43);
    assert_ne!(wls_a, wls_c, "seed has no effect on profiling");
}
