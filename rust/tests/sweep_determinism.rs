//! Sweep determinism invariants: a parallel sweep must be bit-identical
//! to the sequential one for the same master seed (ordered merge +
//! per-task RNG streams), and scenario generation must be a pure
//! function of `(space, master, id)` — stable across runs and across
//! generation order.

use igniter::sweep::{
    profiled_pair, run_sweep, run_task, Fleet, Scenario, ScenarioSpace, SweepConfig,
};

/// A deliberately small space so the property sweeps stay fast: the
/// determinism argument is width-independent, so exercising it on small
/// mixes covers the 1000-workload case too.
fn tiny_space() -> ScenarioSpace {
    ScenarioSpace {
        min_workloads: 6,
        max_workloads: 12,
        epochs: 3,
        epoch_ms: 700.0,
        warmup_ms: 200.0,
        fleets: vec![Fleet::V100Only, Fleet::T4Only, Fleet::Heterogeneous],
        mismatch: false,
        faults: igniter::sim::faults::FaultSpace::OFF,
        longtail: false,
    }
}

fn cfg(master_seed: u64, parallel: usize) -> SweepConfig {
    SweepConfig {
        scenarios: 5,
        seeds: 2,
        parallel,
        master_seed,
        space: tiny_space(),
        calibrate: false,
    }
}

/// The mismatch + calibration lane under the same determinism contract.
fn mismatch_cfg(master_seed: u64, parallel: usize) -> SweepConfig {
    let mut c = cfg(master_seed, parallel);
    c.space.mismatch = true;
    c.calibrate = true;
    c
}

/// The chaos lane (`--faults`) under the same determinism contract.
fn chaos_cfg(master_seed: u64, parallel: usize) -> SweepConfig {
    let mut c = cfg(master_seed, parallel);
    c.space.faults = igniter::sim::faults::FaultSpace::chaos();
    c
}

/// The MIG lane (`--fleet mig`) under the same determinism contract.
fn mig_cfg(master_seed: u64, parallel: usize) -> SweepConfig {
    let mut c = cfg(master_seed, parallel);
    c.space.fleets = vec![Fleet::MigA100, Fleet::MigH100];
    c
}

/// The long-tail lane (`--longtail`) under the same determinism contract
/// — scaled down from the real 200-1000-tenant band so the test stays
/// fast while exercising every longtail-gated draw path.
fn longtail_cfg(master_seed: u64, parallel: usize) -> SweepConfig {
    let mut c = cfg(master_seed, parallel);
    c.space.min_workloads = 20;
    c.space.max_workloads = 40;
    c.space.longtail = true;
    c
}

#[test]
fn property_parallel_sweep_bit_identical_to_sequential() {
    // For random master seeds, --parallel 8 must produce byte-for-byte
    // the same deterministic report as --parallel 1 (and a different
    // master seed must actually change it).
    igniter::util::quick::forall(
        101,
        4,
        |r| r.next_u64(),
        |&seed| {
            let seq = run_sweep(&cfg(seed, 1));
            let par = run_sweep(&cfg(seed, 8));
            if seq.fingerprint() != par.fingerprint() {
                return Err(format!("parallel diverged from sequential (master {seed})"));
            }
            let other = run_sweep(&cfg(seed ^ 0xA5A5, 1));
            if seq.fingerprint() == other.fingerprint() {
                return Err(format!("master seed has no effect ({seed})"));
            }
            Ok(())
        },
    );
}

#[test]
fn parallel_width_never_changes_results() {
    // Same seed across several worker counts — including more workers
    // than tasks — all collapse to one fingerprint.
    let reference = run_sweep(&cfg(7, 1)).fingerprint();
    for parallel in [2, 3, 8, 32] {
        assert_eq!(
            run_sweep(&cfg(7, parallel)).fingerprint(),
            reference,
            "parallel={parallel} diverged"
        );
    }
}

#[test]
fn mismatch_and_calibration_lane_is_deterministic_too() {
    // The model-mismatch lane (perturbed believed coefficients) with
    // online calibration carries extra state (RLS fits, perturbation
    // draws) — none of it may break the parallel == sequential contract,
    // and the lane must actually differ from the plain sweep.
    let seq = run_sweep(&mismatch_cfg(7, 1));
    let par = run_sweep(&mismatch_cfg(7, 8));
    assert_eq!(seq.fingerprint(), par.fingerprint(), "mismatch lane diverged");
    assert_ne!(
        seq.fingerprint(),
        run_sweep(&cfg(7, 1)).fingerprint(),
        "mismatch lane produced the plain sweep"
    );
    for r in &seq.results {
        assert_eq!(r.dropped, 0, "{r:?}");
    }
}

#[test]
fn chaos_lane_is_deterministic_and_distinct() {
    // The `--faults` lane carries the most extra state of any lane —
    // fault plans, breaker trips, failover respecs, hedged routing —
    // and every bit of it must still collapse to one fingerprint across
    // worker counts.  The lane must also actually inject (otherwise the
    // chaos gate gates nothing) and must differ from the plain sweep.
    let seq = run_sweep(&chaos_cfg(7, 1));
    let par = run_sweep(&chaos_cfg(7, 8));
    assert_eq!(seq.fingerprint(), par.fingerprint(), "chaos lane diverged");
    let agg = seq.aggregate();
    assert!(agg.faults_injected > 0, "chaos lane injected nothing");
    assert_ne!(
        seq.fingerprint(),
        run_sweep(&cfg(7, 1)).fingerprint(),
        "chaos lane produced the plain sweep"
    );
    // drops are explicit and bounded, never a silent leak
    assert!(agg.total_dropped >= 0, "negative residual: {agg:?}");
    assert!(
        (agg.total_dropped as u64) <= agg.total_arrivals / 10,
        "chaos lane dropped {} of {}",
        agg.total_dropped,
        agg.total_arrivals
    );
    for r in &seq.results {
        if r.faults_injected == 0 {
            assert_eq!(r.dropped, 0, "dropped without a fired fault: {r:?}");
        }
    }
}

#[test]
fn mig_lane_is_deterministic_and_distinct() {
    // The MIG lane adds a 4-system profiled fleet, slice quantization,
    // the discrete packers, and the head-to-head metrics — all of it
    // must still collapse to one fingerprint across worker counts, and
    // the lane must differ from the plain sweep.
    let seq = run_sweep(&mig_cfg(7, 1));
    let par = run_sweep(&mig_cfg(7, 8));
    assert_eq!(seq.fingerprint(), par.fingerprint(), "MIG lane diverged");
    assert_ne!(
        seq.fingerprint(),
        run_sweep(&cfg(7, 1)).fingerprint(),
        "MIG lane produced the plain sweep"
    );
    let agg = seq.aggregate();
    assert!(agg.mig_tasks > 0, "MIG lane ran no MIG task");
    assert!(
        agg.packer_vs_ffd_cost_ratio > 0.0 && agg.packer_vs_ffd_cost_ratio <= 1.0 + 1e-9,
        "ratio {}",
        agg.packer_vs_ffd_cost_ratio
    );
    for r in &seq.results {
        assert_eq!(r.dropped, 0, "{r:?}");
    }
    // ...and the MIG fleet extension never perturbs a non-MIG sweep: the
    // plain config profiles only the historical pair, so its fingerprint
    // (pinned below in `quick_sweep_fingerprint_pinned_across_refactors`)
    // is the authoritative bit-identity check.
    assert!(!run_sweep(&cfg(7, 1)).fingerprint().contains("mig"));
}

#[test]
fn longtail_lane_is_deterministic_and_distinct() {
    // The long-tail lane rides the idle-aware monitor fast path for most
    // of its tenants — the exact code whose bitwise identity the epochs
    // argument guarantees.  Parallel must equal sequential, the lane must
    // differ from the plain sweep, and the structural numbers must show a
    // genuinely long-tailed population.
    let seq = run_sweep(&longtail_cfg(7, 1));
    let par = run_sweep(&longtail_cfg(7, 8));
    assert_eq!(seq.fingerprint(), par.fingerprint(), "longtail lane diverged");
    assert_ne!(
        seq.fingerprint(),
        run_sweep(&cfg(7, 1)).fingerprint(),
        "longtail lane produced the plain sweep"
    );
    let agg = seq.aggregate();
    assert!(agg.longtail_tasks > 0, "longtail lane ran no longtail task");
    assert!(
        agg.mean_near_idle_fraction > 0.5,
        "near-idle fraction {} — lane is not long-tailed",
        agg.mean_near_idle_fraction
    );
    for r in &seq.results {
        assert_eq!(r.dropped, 0, "{r:?}");
    }
    // ...and the plain sweep never carries long-tail keys: its pinned
    // fingerprint (quick_sweep_fingerprint_pinned_across_refactors) is
    // the authoritative bit-identity check.
    assert!(!run_sweep(&cfg(7, 1)).fingerprint().contains("longtail"));
}

#[test]
fn fault_free_chaos_space_leaves_the_plain_fingerprint_untouched() {
    // Bitwise-inertness at sweep scale: a chaos-space config whose every
    // task happens to draw the empty plan must serialize scenario rows
    // identical to the plain sweep (the config section legitimately
    // differs — it records the lane).  We force empty plans by zeroing
    // the event maxima while keeping the space "on"-shaped.
    let mut c = cfg(7, 1);
    c.space.faults = igniter::sim::faults::FaultSpace {
        max_device_deaths: 0,
        max_stragglers: 0,
        max_hangs: 0,
        ..igniter::sim::faults::FaultSpace::chaos()
    };
    // all maxima zero => is_off() => identical to the plain lane even in
    // the config section
    let zeroed = run_sweep(&c);
    let plain = run_sweep(&cfg(7, 1));
    assert_eq!(
        zeroed.fingerprint(),
        plain.fingerprint(),
        "an empty fault plan perturbed the sweep"
    );
}

#[test]
fn property_scenario_generation_is_pure_and_order_free() {
    // Scenario id `k` generated in isolation must equal scenario `k`
    // generated as part of any enumeration, across random masters.
    let space = tiny_space();
    igniter::util::quick::forall(
        102,
        12,
        |r| (r.next_u64(), r.below(16) as usize),
        |&(master, k)| {
            let batch: Vec<Scenario> = (0..=k)
                .map(|id| Scenario::generate(&space, master, id))
                .collect();
            let alone = Scenario::generate(&space, master, k);
            if batch[k] != alone {
                return Err(format!("scenario {k} depends on generation order"));
            }
            let again = Scenario::generate(&space, master, k);
            if alone != again {
                return Err(format!("scenario {k} unstable across runs"));
            }
            Ok(())
        },
    );
}

#[test]
fn single_task_replays_bit_identically() {
    // The unit of the fan-out is itself deterministic: running task 3
    // twice (fresh profiled pair each time) matches field-for-field,
    // wall-clock aside.
    let c = cfg(13, 1);
    let a = {
        let systems = profiled_pair(42);
        run_task(&c, &systems, 3)
    };
    let b = {
        let systems = profiled_pair(42);
        run_task(&c, &systems, 3)
    };
    // `placements` is deterministic and must replay identically; only
    // the wall clocks are exempt
    assert_eq!(a.placements, b.placements);
    assert!(a.placements > 0, "task performed no placements");
    let strip = |mut r: igniter::sweep::ScenarioResult| {
        r.wall_ms = 0.0;
        r.plan_wall_ms = 0.0;
        r
    };
    assert_eq!(strip(a), strip(b));
}

#[test]
fn quick_sweep_fingerprint_pinned_across_refactors() {
    // Bit-identity across *code versions*, not just across runs: the
    // FNV-1a hash of a small sweep's deterministic fingerprint is pinned
    // to a committed golden.  A sim-core refactor (event queue, request
    // slab, SoA replica state, arrival batching) that changes ANY
    // deterministic byte — event pop order, RNG draw order, float
    // summation order — fails here even though the per-run determinism
    // properties above still pass.  Blessed on first run (see
    // tests/golden/README.md); re-bless by deleting the file.
    let fp = run_sweep(&cfg(4242, 2)).fingerprint();
    let mut hash: u64 = 0xcbf29ce484222325;
    for b in fp.as_bytes() {
        hash ^= *b as u64;
        hash = hash.wrapping_mul(0x100000001b3);
    }
    let digest = format!("{hash:016x}");
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/golden/sweep_fingerprint.txt");
    if !path.exists() {
        std::fs::write(&path, digest + "\n").expect("bless sweep fingerprint golden");
        eprintln!(
            "WARNING: blessed new sweep-fingerprint golden at {} — commit it",
            path.display()
        );
        return;
    }
    let golden = std::fs::read_to_string(&path).expect("read sweep fingerprint golden");
    assert_eq!(
        golden.trim(),
        digest,
        "sweep fingerprint changed: the refactor is NOT bit-identical \
         (delete {} to re-bless only if the change is intended)",
        path.display()
    );
}

#[test]
fn report_json_is_valid_and_consistent() {
    use igniter::util::json::Json;
    let report = run_sweep(&cfg(3, 4));
    let json = report.to_json();
    let parsed = Json::parse(&json.to_string_pretty()).expect("report JSON parses");
    let n = parsed.path("scenarios").unwrap().as_arr().unwrap().len();
    assert_eq!(n, report.results.len());
    assert_eq!(
        parsed.path("aggregate.tasks").unwrap().as_usize(),
        Some(report.results.len())
    );
    // conservation surfaces in the report: nothing dropped anywhere
    assert_eq!(parsed.path("aggregate.total_dropped").unwrap().as_f64(), Some(0.0));
    // wall section present but quarantined from the fingerprint
    assert!(parsed.path("wall.wall_s").unwrap().as_f64().unwrap() >= 0.0);
    assert!(!report.fingerprint().contains("wall_ms"));
    // the placement-engine throughput is measured and nonzero, and its
    // inputs stay out of the deterministic subset with the other wall data
    assert!(parsed.path("wall.plan_throughput_pps").unwrap().as_f64().unwrap() > 0.0);
    assert!(parsed.path("wall.total_placements").unwrap().as_u64().unwrap() > 0);
    assert!(!report.fingerprint().contains("placements"));
    assert!(!report.fingerprint().contains("plan_wall_ms"));
}
