//! Property-based invariants of the provisioning layer (proptest-lite via
//! `igniter::util::quick`): random SLO/rate workload sets must always yield
//! structurally valid, SLO-meeting, deterministic plans.

use igniter::gpu::{GpuKind, Model, ALL_MODELS};
use igniter::perfmodel::{self, AnalyticModel};
use igniter::provisioner::{
    ffd, gpulets, igniter as ig, OnlinePlanner, ProfiledSystem, WorkloadSpec,
};
use igniter::util::quick::{forall, Shrink};
use igniter::util::rng::Rng;
use igniter::util::lazy::Lazy;

static SYS: Lazy<ProfiledSystem> = Lazy::new(|| {
    let (hw, wls) = igniter::profiler::profile_all(GpuKind::V100, 42);
    ProfiledSystem {
        hw,
        coeffs: ALL_MODELS.iter().cloned().zip(wls).collect(),
    }
});

/// A random feasible workload description for property generation.
#[derive(Debug, Clone)]
struct GenSpec {
    model_idx: usize,
    slo_ms: f64,
    rate_rps: f64,
}

impl Shrink for GenSpec {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.rate_rps > 50.0 {
            out.push(GenSpec {
                rate_rps: self.rate_rps / 2.0,
                ..self.clone()
            });
        }
        if self.slo_ms < 100.0 {
            out.push(GenSpec {
                slo_ms: self.slo_ms * 1.5,
                ..self.clone()
            });
        }
        out
    }
}

fn gen_specs(r: &mut Rng) -> Vec<GenSpec> {
    let n = 1 + r.below(10) as usize;
    (0..n)
        .map(|_| {
            let model_idx = r.below(4) as usize;
            // SLO/rate bands chosen to be individually feasible on a V100
            let (slo_lo, slo_hi, rate_lo, rate_hi) = match ALL_MODELS[model_idx] {
                Model::AlexNet => (10.0, 30.0, 100.0, 1200.0),
                Model::ResNet50 => (20.0, 50.0, 100.0, 600.0),
                Model::Vgg19 => (25.0, 60.0, 50.0, 400.0),
                Model::Ssd => (30.0, 60.0, 30.0, 300.0),
            };
            GenSpec {
                model_idx,
                slo_ms: r.range_f64(slo_lo, slo_hi),
                rate_rps: r.range_f64(rate_lo, rate_hi).round(),
            }
        })
        .collect()
}

fn to_specs(gs: &[GenSpec]) -> Vec<WorkloadSpec> {
    gs.iter()
        .enumerate()
        .map(|(i, g)| WorkloadSpec::new(i, ALL_MODELS[g.model_idx], g.slo_ms, g.rate_rps))
        .collect()
}

#[test]
fn igniter_plans_always_valid_and_slo_meeting() {
    forall(101, 60, gen_specs, |gs| {
        let specs = to_specs(gs);
        let plan = ig::provision(&SYS, &specs);
        plan.validate(specs.len(), SYS.hw.r_max)
            .map_err(|e| format!("invalid plan: {e}"))?;
        for (w, t_inf, thpt) in ig::predict_plan(&SYS, &specs, &plan) {
            if t_inf > specs[w].slo_ms / 2.0 + 1e-6 {
                return Err(format!(
                    "{}: predicted {t_inf:.2} ms > half-SLO {:.2}",
                    specs[w].name,
                    specs[w].slo_ms / 2.0
                ));
            }
            if thpt < specs[w].rate_rps * 0.999 {
                return Err(format!("{}: throughput {thpt:.0}", specs[w].name));
            }
        }
        Ok(())
    });
}

#[test]
fn plans_are_deterministic() {
    forall(202, 30, gen_specs, |gs| {
        let specs = to_specs(gs);
        if ig::provision(&SYS, &specs) != ig::provision(&SYS, &specs) {
            return Err("igniter non-deterministic".into());
        }
        if gpulets::provision_gpulets(&SYS, &specs) != gpulets::provision_gpulets(&SYS, &specs) {
            return Err("gpulets non-deterministic".into());
        }
        Ok(())
    });
}

#[test]
fn ffd_never_more_gpus_than_igniter_never_less_resources() {
    forall(303, 40, gen_specs, |gs| {
        let specs = to_specs(gs);
        let ffd_plan = ffd::provision_ffd(&SYS, &specs);
        let ig_plan = ig::provision(&SYS, &specs);
        ffd_plan
            .validate(specs.len(), SYS.hw.r_max)
            .map_err(|e| format!("ffd invalid: {e}"))?;
        if ffd_plan.num_gpus() > ig_plan.num_gpus() {
            return Err(format!(
                "FFD used more GPUs ({}) than iGniter ({})",
                ffd_plan.num_gpus(),
                ig_plan.num_gpus()
            ));
        }
        // iGniter never allocates less than the lower bound
        let derived = ig::derive_all(&SYS, &specs);
        for (_, a) in ig_plan.all() {
            let d = derived[a.workload].unwrap();
            if a.resources < d.r_lower - 1e-9 {
                return Err(format!(
                    "w{} allocated {} < lower bound {}",
                    a.workload, a.resources, d.r_lower
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn eq17_18_monotonicity() {
    // b_appr grows with rate; r_lower grows as the SLO tightens.
    forall(404, 80, |r: &mut Rng| (r.below(3), r.range_f64(25.0, 60.0)), |&(mi, slo)| {
        let model = ALL_MODELS[mi as usize];
        let wc = SYS.coeffs_for(model);
        let b1 = perfmodel::appropriate_batch(&SYS.hw, wc, slo, 100.0);
        let b2 = perfmodel::appropriate_batch(&SYS.hw, wc, slo, 400.0);
        if b1 > b2 {
            return Err(format!("batch not monotone in rate: {b1} > {b2}"));
        }
        // (b_appr, r_lower) must be *feasible and tight*: within the
        // half-SLO and meeting the rate.  Note r_lower is NOT monotone in
        // the SLO — a looser SLO grows b_appr (Eq. 17), which can require
        // marginally more resources; only feasibility is guaranteed.
        for slo_k in [1.0, 1.5] {
            if let Some((b, r)) =
                perfmodel::lower_bound_resources(&SYS.hw, wc, slo * slo_k, 200.0)
            {
                let p = perfmodel::predict_solo(&SYS.hw, wc, b as f64, r);
                if p.t_inf > slo * slo_k / 2.0 + 1e-6 {
                    return Err(format!("infeasible bound: {} > {}", p.t_inf, slo * slo_k / 2.0));
                }
                if p.throughput_rps < 200.0 * 0.999 {
                    return Err(format!("rate missed: {}", p.throughput_rps));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn alloc_gpus_supersets_never_shrink() {
    // Adding a workload through Alg. 2 must never *reduce* any resident's
    // allocation.
    forall(505, 40, gen_specs, |gs| {
        if gs.len() < 2 {
            return Ok(());
        }
        let specs = to_specs(gs);
        let derived = ig::derive_all(&SYS, &specs);
        let d0 = derived[0].unwrap();
        let resident = vec![igniter::provisioner::Alloc {
            workload: 0,
            resources: d0.r_lower,
            batch: d0.batch,
        }];
        let d1 = derived[1].unwrap();
        let alloc = ig::alloc_gpus(
            &AnalyticModel::ALL,
            &SYS,
            &specs,
            &resident,
            1,
            d1.r_lower,
            d1.batch,
        );
        if let Some(alloc) = alloc {
            let r0 = alloc.iter().find(|a| a.workload == 0).unwrap().resources;
            if r0 < d0.r_lower - 1e-9 {
                return Err(format!("resident shrunk from {} to {}", d0.r_lower, r0));
            }
            let total: f64 = alloc.iter().map(|a| a.resources).sum();
            if total > SYS.hw.r_max + 1e-9 {
                return Err(format!("over-allocated: {total}"));
            }
        }
        Ok(())
    });
}

/// One step of a random online-planner history.
#[derive(Debug, Clone)]
struct OnlineOp {
    /// 0..=4 add, 5 remove, 6 respec, 7 rebalance
    action: u8,
    spec: GenSpec,
    /// which live workload a remove/respec targets (mod live count)
    pick: usize,
}

impl Shrink for OnlineOp {
    fn shrink(&self) -> Vec<Self> {
        self.spec
            .shrink()
            .into_iter()
            .map(|spec| OnlineOp {
                spec,
                ..self.clone()
            })
            .collect()
    }
}

fn gen_online_ops(r: &mut Rng) -> Vec<OnlineOp> {
    let n = 2 + r.below(18) as usize;
    (0..n)
        .map(|_| {
            let spec = gen_specs(r).pop().unwrap();
            OnlineOp {
                action: r.below(8) as u8,
                spec,
                pick: r.below(32) as usize,
            }
        })
        .collect()
}

#[test]
fn online_planner_never_overcommits_and_keeps_slos() {
    // Any sequence of arrivals, departures, rate re-specs, and rebalances
    // must leave (a) every device within its physical partition budget
    // (sum of partitions <= r_max, i.e. 100 %) and (b) every active
    // workload with a predicted-SLO-feasible allocation for its rate.
    forall(707, 30, gen_online_ops, |ops| {
        let mut op = OnlinePlanner::new((*SYS).clone());
        let mut live: Vec<usize> = Vec::new();
        for (step, o) in ops.iter().enumerate() {
            let model = ALL_MODELS[o.spec.model_idx];
            match o.action {
                0..=4 => {
                    let spec = WorkloadSpec::new(0, model, o.spec.slo_ms, o.spec.rate_rps);
                    let id = op
                        .add(spec)
                        .map_err(|e| format!("step {step}: feasible add rejected: {e}"))?
                        .0;
                    live.push(id);
                }
                5 => {
                    if !live.is_empty() {
                        let id = live.remove(o.pick % live.len());
                        op.remove(id)
                            .map_err(|e| format!("step {step}: remove failed: {e}"))?;
                    }
                }
                6 => {
                    if !live.is_empty() {
                        let i = o.pick % live.len();
                        // the random rate may be infeasible for *this*
                        // workload's model/SLO (bands differ per model);
                        // a rejected respec must leave the planner
                        // untouched — invariant (b) below proves it did
                        if let Ok((id, _)) = op.respec(live[i], o.spec.rate_rps) {
                            live[i] = id;
                        }
                    }
                }
                _ => {
                    op.rebalance();
                }
            }
            // (a) no overcommitted device, ever
            for g in 0..op.plan().gpus.len() {
                let total = op.plan().allocated(g);
                if total > SYS.hw.r_max + 1e-6 {
                    return Err(format!(
                        "step {step}: gpu {g} overcommitted at {total:.4}"
                    ));
                }
            }
            // (b) every active workload stays predicted-SLO feasible
            for &id in &live {
                let (t_inf, thpt) = op
                    .predict(id)
                    .ok_or(format!("step {step}: workload {id} lost its allocation"))?;
                let spec = &op.specs()[id];
                if t_inf > spec.slo_ms / 2.0 + 1e-6 {
                    return Err(format!(
                        "step {step}: {} predicted {t_inf:.2} ms > half-SLO {:.2}",
                        spec.name,
                        spec.slo_ms / 2.0
                    ));
                }
                // predict() reports the first replica; a respec onto a
                // cross-band rate may replica-split, so the group's
                // capacity is per-share throughput x replica count
                let k = op.plan().replica_count(id).max(1);
                if thpt * k as f64 < spec.rate_rps * 0.999 {
                    return Err(format!(
                        "step {step}: {} group capacity {:.0} (x{k}) < rate {:.0}",
                        spec.name,
                        thpt * k as f64,
                        spec.rate_rps
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn indexed_placement_matches_linear_reference_on_sweep_scenarios() {
    // The PR-7 differential pin at integration scale: over random quick()
    // scenarios and a capped full()-space sample, the engine-backed
    // provisioning path (headroom index + persistent scorers + admissible
    // pruning) must produce plans equal to the retained exhaustive scan —
    // f64-equal allocation by allocation (`Plan: PartialEq`), on every
    // profiled GPU type, through the same heterogeneous front-end the
    // sweep runner uses (replicate_for -> derive_all ->
    // provision_with_derived).
    use igniter::provisioner::heterogeneous;
    use igniter::sweep::{Scenario, ScenarioSpace};

    let pair = igniter::sweep::profiled_pair(42);
    let mut small_full = ScenarioSpace::full();
    // the linear reference is ~quadratic in fleet size — cap the mix so
    // the reference side stays test-budget sized while still exercising
    // fleets an order of magnitude past quick()
    small_full.min_workloads = 60;
    small_full.max_workloads = 120;
    let lanes: [(&ScenarioSpace, u64, usize); 2] =
        [(&ScenarioSpace::quick(), 9001, 5), (&small_full, 9002, 2)];

    for (space, master, count) in lanes {
        for id in 0..count {
            let scenario = Scenario::generate(space, master, id);
            for sys in &pair {
                let Some(replicated) = heterogeneous::replicate_for(sys, &scenario.specs) else {
                    continue; // infeasible on this GPU type
                };
                let derived = ig::derive_all(sys, &replicated.specs);
                if derived.iter().any(|d| d.is_none()) {
                    continue;
                }
                let indexed =
                    ig::provision_with_derived(&AnalyticModel::ALL, sys, &replicated.specs, &derived);
                let linear = ig::provision_with_derived_linear(
                    &AnalyticModel::ALL,
                    sys,
                    &replicated.specs,
                    &derived,
                );
                assert_eq!(
                    indexed, linear,
                    "engine diverged on scenario {id} (master {master}) on {}",
                    sys.hw.gpu
                );
            }
        }
    }
}

#[test]
fn indexed_provision_matches_linear_through_replica_splitting_front_end() {
    // Same pin through provision_with's own replica-splitting expansion
    // (the offline path the OnlinePlanner's rebalance also takes).
    forall(808, 25, gen_specs, |gs| {
        let specs = to_specs(gs);
        let indexed = ig::provision_with(&AnalyticModel::ALL, &SYS, &specs);
        let linear = ig::provision_with_linear(&AnalyticModel::ALL, &SYS, &specs);
        if indexed != linear {
            return Err(format!(
                "engine diverged: {} vs {} GPUs",
                indexed.num_gpus(),
                linear.num_gpus()
            ));
        }
        Ok(())
    });
}

#[test]
fn gpulets_structural_invariants() {
    forall(606, 40, gen_specs, |gs| {
        let specs = to_specs(gs);
        let plan = gpulets::provision_gpulets(&SYS, &specs);
        plan.validate(specs.len(), SYS.hw.r_max)
            .map_err(|e| format!("gpulets invalid: {e}"))?;
        for g in &plan.gpus {
            if g.len() > 2 {
                return Err(format!("{} workloads on one GPU", g.len()));
            }
        }
        for (_, a) in plan.all() {
            if !gpulets::GPULETS_CHOICES
                .iter()
                .any(|&c| (c - a.resources).abs() < 1e-9)
            {
                return Err(format!("resource {} off-menu", a.resources));
            }
        }
        Ok(())
    });
}
