//! Serving-coordinator invariants under randomized arrival processes:
//! request conservation, latency lower bounds, batch-size caps, and shadow
//! failover semantics.

use igniter::coordinator::{ClusterSim, Policy, Reprovisioner};
use igniter::gpu::{GpuKind, Model, ALL_MODELS};
use igniter::provisioner::{igniter as ig, ProfiledSystem, WorkloadSpec};
use igniter::util::lazy::Lazy;
use igniter::util::quick::forall;
use igniter::workload::trace::{RateTrace, TraceKind};
use igniter::workload::{app_workloads, table1_workloads, ArrivalKind};

static SYS: Lazy<ProfiledSystem> = Lazy::new(|| {
    let (hw, wls) = igniter::profiler::profile_all(GpuKind::V100, 42);
    ProfiledSystem {
        hw,
        coeffs: ALL_MODELS.iter().cloned().zip(wls).collect(),
    }
});

#[test]
fn request_conservation_and_rate_tracking() {
    // Across random seeds and both arrival processes, the served request
    // rate per workload must track the arrival rate (the plan is sized to
    // sustain it), and latencies must exceed the physical minimum.
    let specs = table1_workloads();
    let plan = ig::provision(&SYS, &specs);
    forall(
        11,
        8,
        |r| (r.next_u64(), r.bool()),
        |&(seed, poisson)| {
            let arrival = if poisson {
                ArrivalKind::Poisson
            } else {
                ArrivalKind::Constant
            };
            let mut sim = ClusterSim::new(
                GpuKind::V100,
                &plan,
                &specs,
                Policy::Static,
                arrival,
                seed,
                &[],
            );
            sim.set_horizon(6_000.0, 1_000.0);
            let stats = sim.run();
            for (s, spec) in stats.iter().zip(specs.iter()) {
                // 5 s of recording, warmup excluded: within 15 % of rate
                let expect = spec.rate_rps;
                if (s.achieved_rps - expect).abs() > expect * 0.15 {
                    return Err(format!(
                        "{}: achieved {:.0} vs rate {expect} (seed {seed})",
                        s.name, s.achieved_rps
                    ));
                }
                if s.mean_ms <= 0.0 || !s.mean_ms.is_finite() {
                    return Err(format!("{}: bad mean {}", s.name, s.mean_ms));
                }
                // latency can never beat the PCIe floor of a single request
                let prof = igniter::gpu::profile(spec.model, GpuKind::V100);
                let spec_hw = igniter::gpu::GpuSpec::v100();
                let floor = prof.load_ms(&spec_hw, 1.0);
                if s.p99_ms < floor {
                    return Err(format!("{}: p99 {} below floor {floor}", s.name, s.p99_ms));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn shadow_failover_restores_slo() {
    // For any mild injected under-provisioning, the shadow mechanism must
    // fire at most once per workload and the post-switch tail must meet
    // the SLO.
    let specs = table1_workloads();
    let plan = ig::provision(&SYS, &specs);
    forall(
        22,
        6,
        |r| (r.below(3) as usize, 0.025 + 0.025 * r.below(3) as f64),
        |&(victim, shave)| {
            let mut sim = ClusterSim::new(
                GpuKind::V100,
                &plan,
                &specs,
                Policy::IgniterShadow,
                ArrivalKind::Constant,
                7,
                &[(victim, shave)],
            );
            sim.set_horizon(12_000.0, 1_000.0);
            let stats = sim.run();
            for s in &stats {
                if s.shadow_switches > 1 {
                    return Err(format!("{}: {} switches", s.name, s.shadow_switches));
                }
            }
            // tail after 9 s must be within SLO for the victim
            let tail: Vec<f64> = stats[victim]
                .timeline
                .iter()
                .filter(|t| t.t_ms > 9_000.0 && t.p99_ms.is_finite())
                .map(|t| t.p99_ms)
                .collect();
            if tail.is_empty() {
                return Err("no tail samples".into());
            }
            let worst = tail.iter().cloned().fold(0.0, f64::max);
            if worst > specs[victim].slo_ms * 1.1 {
                return Err(format!(
                    "victim {} tail P99 {worst:.2} after shadow switch",
                    specs[victim].name
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn full_app_table_serving_meets_slos_across_seeds() {
    let specs = app_workloads();
    let plan = ig::provision(&SYS, &specs);
    for seed in [1u64, 99, 12345] {
        let mut sim = ClusterSim::new(
            GpuKind::V100,
            &plan,
            &specs,
            Policy::IgniterShadow,
            ArrivalKind::Constant,
            seed,
            &[],
        );
        sim.set_horizon(10_000.0, 1_000.0);
        let stats = sim.run();
        let violations: Vec<&str> = stats
            .iter()
            .filter(|s| s.violation || s.throughput_violation)
            .map(|s| s.name.as_str())
            .collect();
        assert!(violations.is_empty(), "seed {seed}: {violations:?}");
    }
}

#[test]
fn batch_sizes_respected() {
    // No dispatched batch may exceed the configured preferred batch size;
    // we check via timeline throughput consistency: served requests per
    // busy period <= batch.  (Indirect: total served <= arrivals.)
    let specs = table1_workloads();
    let plan = ig::provision(&SYS, &specs);
    let mut sim = ClusterSim::new(
        GpuKind::V100,
        &plan,
        &specs,
        Policy::Static,
        ArrivalKind::Constant,
        3,
        &[],
    );
    sim.set_horizon(5_000.0, 0.0);
    let stats = sim.run();
    for (s, spec) in stats.iter().zip(specs.iter()) {
        let max_arrivals = (spec.rate_rps * 5.0 * 1.01) as u64 + 2;
        assert!(
            s.served <= max_arrivals,
            "{}: served {} > arrivals {max_arrivals}",
            s.name,
            s.served
        );
        assert!(s.served > 0);
    }
}

#[test]
fn shadow_with_no_headroom_still_switches() {
    // Failure injection: fill the victim's device completely so the shadow
    // gets zero extra resources — the switch must still happen (process
    // restart) without panicking or over-allocating.
    let specs = table1_workloads();
    let mut plan = ig::provision(&SYS, &specs);
    // inflate every allocation on GPU0 so the device is exactly full
    let free: f64 = 1.0 - plan.allocated(0);
    if free > 0.0 {
        plan.gpus[0][0].resources += free;
    }
    let mut sim = ClusterSim::new(
        GpuKind::V100,
        &plan,
        &specs,
        Policy::IgniterShadow,
        ArrivalKind::Constant,
        5,
        &[(0, 0.10)], // big injected error on W1
    );
    sim.set_horizon(8_000.0, 1_000.0);
    let stats = sim.run();
    // no device may end oversubscribed after the switch
    // (shadow extra is capped by the remaining headroom)
    assert!(stats[0].shadow_switches <= 1);
    assert!(stats[0].final_resources <= 1.0 + 1e-9);
}

#[test]
fn over_capacity_workload_replicates_and_meets_slo() {
    // A workload whose rate exceeds what a single V100 gpulet can sustain
    // must provision >= 2 rate-sharing replicas (possibly on different
    // GPUs) and still meet its P99 SLO end-to-end through the
    // router/batcher/monitor pipeline.
    let rate = ig::over_capacity_rate(&SYS, Model::ResNet50, 40.0, 400.0);
    let specs = vec![WorkloadSpec::new(0, Model::ResNet50, 40.0, rate)];
    let plan = ig::provision(&SYS, &specs);
    assert!(
        plan.replica_count(0) >= 2,
        "rate {rate:.0} should need replicas: {plan:?}"
    );
    plan.validate(1, SYS.hw.r_max).unwrap();
    ig::validate_replica_shares(&igniter::perfmodel::AnalyticModel::ALL, &SYS, &specs, &plan)
        .unwrap();

    let mut sim = ClusterSim::new(
        GpuKind::V100,
        &plan,
        &specs,
        Policy::IgniterShadow,
        ArrivalKind::Constant,
        17,
        &[],
    );
    sim.set_horizon(10_000.0, 1_000.0);
    let stats = sim.run();
    assert_eq!(stats.len(), 1, "stats aggregate per workload");
    assert!(
        !stats[0].violation,
        "P99 {:.2} > SLO {:.0}",
        stats[0].p99_ms, specs[0].slo_ms
    );
    assert!(
        !stats[0].throughput_violation,
        "achieved {:.0} < rate {rate:.0}",
        stats[0].achieved_rps
    );
    assert_eq!(stats[0].replica_served.len(), plan.replica_count(0));
    assert!(
        stats[0].replica_served.iter().all(|&s| s > 0),
        "a replica was starved: {:?}",
        stats[0].replica_served
    );
}

#[test]
fn request_conservation_property() {
    // Arrivals observed inside the horizon == served + still-queued
    // (waiting or in flight) per workload, across random seeds, rate
    // scalings (including overload), and all three serving policies.
    let base = table1_workloads();
    let plan = ig::provision(&SYS, &base);
    forall(
        33,
        10,
        |r| ((r.next_u64(), 0.2 + 2.8 * r.f64()), r.below(3)),
        |&((seed, scale), policy_idx)| {
            let mut specs = table1_workloads();
            for s in &mut specs {
                s.rate_rps = (s.rate_rps * scale).max(1.0);
            }
            let policy = match policy_idx {
                0 => Policy::Static,
                1 => Policy::IgniterShadow,
                _ => Policy::GsliceTuner { period_ms: 2_000.0 },
            };
            let arrival = if seed % 2 == 0 {
                ArrivalKind::Constant
            } else {
                ArrivalKind::Poisson
            };
            let mut sim =
                ClusterSim::new(GpuKind::V100, &plan, &specs, policy, arrival, seed, &[]);
            sim.set_horizon(5_000.0, 500.0);
            for st in sim.run() {
                if st.arrivals != st.served + st.still_queued {
                    return Err(format!(
                        "{}: arrivals {} != served {} + queued {} (seed {seed}, x{scale:.2})",
                        st.name, st.arrivals, st.served, st.still_queued
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn migration_conserves_requests_under_spiky_replans() {
    // Under a Spiky trace the closed loop is forced through repeated
    // re-plans (bursts to nominal trigger up-respecs, the quiet base
    // triggers down-respecs).  Across every shadow migration:
    //   * arrivals == served + still_queued per workload (zero drops);
    //   * lifetime P99 spans the switches (the retired replicas' records
    //     stay in the merged histogram — served splits prove they ran).
    let specs = table1_workloads();
    // provision for 40% of nominal so the 1.0x bursts overrun the plan
    let provisioned: Vec<WorkloadSpec> = specs
        .iter()
        .map(|s| {
            let mut c = s.clone();
            c.rate_rps = (s.rate_rps * 0.4).max(1.0);
            c
        })
        .collect();
    let plan = ig::provision(&SYS, &provisioned);
    let trace = RateTrace::generate(
        TraceKind::Spiky { base: 0.35, p: 0.4 },
        8,
        specs.len(),
        13,
    );
    let mut sim = ClusterSim::new(
        GpuKind::V100,
        &plan,
        &specs,
        Policy::Static,
        ArrivalKind::Poisson,
        13,
        &[],
    );
    sim.set_serving_policy(Box::new(Reprovisioner::new(
        (*SYS).clone(),
        provisioned,
        plan.clone(),
    )));
    sim.set_rate_trace(&trace, 3_000.0);
    sim.set_horizon(24_000.0, 1_000.0);
    let stats = sim.run();

    assert!(
        sim.migrations() >= 2,
        "spiky trace forced only {} re-plans",
        sim.migrations()
    );
    for st in &stats {
        assert_eq!(
            st.arrivals,
            st.served + st.still_queued,
            "{}: dropped {} requests across migrations",
            st.name,
            st.arrivals as i64 - st.served as i64 - st.still_queued as i64
        );
        assert!(st.p99_ms.is_finite() && st.p99_ms > 0.0, "{}: no lifetime P99", st.name);
        assert_eq!(
            st.served,
            st.replica_served.iter().sum::<u64>(),
            "{}: retired replicas fell out of the aggregate",
            st.name
        );
    }
    // at least one workload's group actually grew across a migration,
    // with both the retired and the fresh replica having served traffic
    assert!(
        stats.iter().any(|st| {
            st.replica_served.len() >= 2
                && st.replica_served.iter().filter(|&&s| s > 0).count() >= 2
        }),
        "no workload shows a served split across the shadow switch: {:?}",
        stats.iter().map(|s| s.replica_served.clone()).collect::<Vec<_>>()
    );
}

#[test]
fn request_conservation_holds_under_random_fault_plans() {
    // The chaos-layer ledger: for any sampled `FaultPlan` (device deaths,
    // stragglers, hangs) served with full resilience,
    //   arrivals == served + still_queued + dropped
    // per workload — every request lost to a fault is counted explicitly,
    // never silently — and the residual `dropped_requests` equals the
    // explicit per-workload counts exactly.  Fault-free tasks must not
    // drop anything.
    use igniter::coordinator::{dropped_requests, Resilience};
    use igniter::sim::faults::{FaultPlan, FaultSpace};

    let specs = table1_workloads();
    let plan = ig::provision(&SYS, &specs);
    let space = FaultSpace::chaos();
    forall(
        44,
        8,
        |r| (r.next_u64(), r.below(64) as usize),
        |&(master, id)| {
            let fplan = FaultPlan::generate(&space, master, id, 12_000.0);
            let scheduled = fplan.len() as u64;
            let mut sim = ClusterSim::new(
                GpuKind::V100,
                &plan,
                &specs,
                Policy::Static,
                ArrivalKind::Poisson,
                master ^ 0xD1CE,
                &[],
            );
            sim.set_serving_policy(Box::new(
                Reprovisioner::new((*SYS).clone(), specs.clone(), plan.clone())
                    .with_resilience(Resilience::ALL),
            ));
            sim.set_fault_plan(fplan);
            sim.set_horizon(12_000.0, 1_000.0);
            let stats = sim.run();
            for st in &stats {
                if st.arrivals != st.served + st.still_queued + st.dropped {
                    return Err(format!(
                        "{}: arrivals {} != served {} + queued {} + dropped {} \
                         (master {master}, id {id})",
                        st.name, st.arrivals, st.served, st.still_queued, st.dropped
                    ));
                }
            }
            let injected = sim.faults_injected();
            if injected > scheduled {
                return Err(format!(
                    "injected {injected} > scheduled {scheduled} (master {master}, id {id})"
                ));
            }
            let explicit: u64 = stats.iter().map(|s| s.dropped).sum();
            let residual = dropped_requests(&stats);
            if residual != explicit as i64 {
                return Err(format!(
                    "residual {residual} != explicit dropped {explicit} (master {master}, id {id})"
                ));
            }
            if injected == 0 && explicit != 0 {
                return Err(format!(
                    "dropped {explicit} with no fault fired (master {master}, id {id})"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn zero_rate_edge_is_handled() {
    // A workload with a tiny rate must not wedge the batcher (timeout
    // dispatch path) nor divide by zero anywhere.
    let mut specs = table1_workloads();
    specs[0].rate_rps = 2.0; // 1 request per 500 ms
    let plan = ig::provision(&SYS, &specs);
    let mut sim = ClusterSim::new(
        GpuKind::V100,
        &plan,
        &specs,
        Policy::Static,
        ArrivalKind::Constant,
        9,
        &[],
    );
    sim.set_horizon(6_000.0, 1_000.0);
    let stats = sim.run();
    assert!(stats[0].served >= 5, "only {} served", stats[0].served);
    assert!(!stats[0].violation, "P99 {:.2}", stats[0].p99_ms);
}
