//! Integration: AOT HLO-text artifacts round-trip through the PJRT engine
//! and match the Python-produced golden outputs (the core numerics signal).

use igniter::runtime::{Engine, Manifest};
use std::path::Path;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    if !igniter::runtime::PJRT_AVAILABLE {
        eprintln!("skipping: PJRT runtime stubbed (see DESIGN.md §PJRT runtime)");
        return None;
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn golden_numerics_match_python() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let names: Vec<String> = manifest.models.iter().map(|m| m.name.clone()).collect();
    let mut engine = Engine::new(manifest).unwrap();
    for name in &names {
        let err = engine.verify_golden(name, 1e-3).unwrap();
        eprintln!("{name}: golden max |err| = {err:.2e}");
    }
}

#[test]
fn padded_execution_matches_full() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let Some(model) = manifest.models.first().map(|m| m.name.clone()) else {
        return;
    };
    let art = manifest.model(&model).unwrap().clone();
    let Some(v4) = art.variants.iter().find(|v| v.batch >= 2) else {
        eprintln!("skipping: no batch>=2 variant");
        return;
    };
    let batch = v4.batch;
    let mut engine = Engine::new(manifest).unwrap();
    engine.load_variant(&model, batch).unwrap();
    let lv = engine.variant(&model, batch).unwrap();

    let per_in = lv.variant.input_len() / batch;
    let per_out = lv.variant.output_len() / batch;
    // 1 real request + zero padding == full batch where request 0 matches
    let req: Vec<f32> = (0..per_in).map(|i| (i % 7) as f32 * 0.1).collect();
    let padded = lv.execute_padded(&req, 1).unwrap();
    assert_eq!(padded.len(), per_out);

    let mut full = vec![0f32; lv.variant.input_len()];
    full[..per_in].copy_from_slice(&req);
    let full_out = lv.execute(&full).unwrap();
    for (a, b) in padded.iter().zip(full_out[..per_out].iter()) {
        assert!((a - b).abs() < 1e-5, "padded/full mismatch: {a} vs {b}");
    }
}
