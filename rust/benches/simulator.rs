//! Bench: GPU-simulator and DES hot paths — `query_latency` (called for
//! every dispatched batch), telemetry, the profiler sweep, and raw event
//! queue throughput.  These bound how long the Fig.-14-style serving
//! experiments take.

use igniter::gpu::{GpuDevice, GpuKind, Model};
use igniter::sim::EventQueue;
use igniter::util::bench::bench;

fn main() {
    println!("== simulator benches ==");

    let mut d = GpuDevice::new(GpuKind::V100, 7);
    for i in 0..4 {
        d.launch(i, Model::ResNet50, 0.25, 8);
    }
    bench("query_latency(4 co-located)", 1000, 20_000, || {
        d.query_latency(0, 8).unwrap()
    });

    let d2 = d.clone();
    bench("power_demand + frequency", 1000, 20_000, || {
        (d2.power_demand_w(), d2.frequency_mhz())
    });
    bench("telemetry snapshot", 1000, 20_000, || d2.telemetry());

    bench("profile_workload(11 configs)", 2, 20, || {
        igniter::profiler::profile_workload(Model::Vgg19, GpuKind::V100, 42)
    });

    bench("event_queue push+pop x1000", 10, 500, || {
        let mut q: EventQueue<u64> = EventQueue::new();
        for i in 0..1000u64 {
            q.schedule_at((i % 97) as f64, i);
        }
        let mut acc = 0u64;
        while let Some((_, e)) = q.pop() {
            acc = acc.wrapping_add(e);
        }
        acc
    });
}
