//! Bench: GPU-simulator and DES hot paths — `query_latency` (called for
//! every dispatched batch), telemetry, the profiler sweep, and raw event
//! queue throughput.  These bound how long the Fig.-14-style serving
//! experiments take.

use igniter::coordinator::{ClusterSim, Policy, Reprovisioner, Resilience};
use igniter::gpu::{GpuDevice, GpuKind, Model};
use igniter::provisioner::{self, ProfiledSystem};
use igniter::sim::faults::{FaultPlan, FaultSpace};
use igniter::sim::EventQueue;
use igniter::util::bench::{bench, bench_once};
use igniter::workload::trace::{RateTrace, TraceKind};
use igniter::workload::{app_workloads, ArrivalKind};

fn main() {
    println!("== simulator benches ==");

    let mut d = GpuDevice::new(GpuKind::V100, 7);
    for i in 0..4 {
        d.launch(i, Model::ResNet50, 0.25, 8);
    }
    bench("query_latency(4 co-located)", 1000, 20_000, || {
        d.query_latency(0, 8).unwrap()
    });

    let d2 = d.clone();
    bench("power_demand + frequency", 1000, 20_000, || {
        (d2.power_demand_w(), d2.frequency_mhz())
    });
    bench("telemetry snapshot", 1000, 20_000, || d2.telemetry());

    bench("profile_workload(11 configs)", 2, 20, || {
        igniter::profiler::profile_workload(Model::Vgg19, GpuKind::V100, 42)
    });

    bench("event_queue push+pop x1000", 10, 500, || {
        let mut q: EventQueue<u64> = EventQueue::new();
        for i in 0..1000u64 {
            q.schedule_at((i % 97) as f64, i);
        }
        let mut acc = 0u64;
        while let Some((_, e)) = q.pop() {
            acc = acc.wrapping_add(e);
        }
        acc
    });

    // Interleaved schedule/pop with a spread of horizons: near events hit
    // the ring, monitor ticks land hundreds of buckets out, and the
    // horizon event routes through the overflow heap — the access pattern
    // the calendar queue is shaped around, unlike the drain-only bench
    // above.
    bench("event_queue calendar mix x4000", 10, 200, || {
        let mut q: EventQueue<u64> = EventQueue::new();
        q.schedule_at(60_000.0, u64::MAX); // horizon, via overflow
        for i in 0..64u64 {
            q.schedule_at((i % 13) as f64, i);
        }
        let mut acc = 0u64;
        let mut n = 0u32;
        while let Some((now, e)) = q.pop() {
            acc = acc.wrapping_add(e);
            n += 1;
            if n > 4_000 || e == u64::MAX {
                break;
            }
            // completion-style short hop + occasional monitor-style tick
            q.schedule_at(now + 2.5 + (e % 7) as f64, e + 1);
            if e % 16 == 0 {
                q.schedule_at(now + 500.0, e + 2);
            }
        }
        acc
    });

    // End-to-end sim-core throughput: the whole closed loop (batched
    // arrivals -> slab queues -> SoA replicas -> calendar queue ->
    // reprovisioner) on a 30 s diurnal trace, reported as simulated
    // served requests per wall-second — the same metric
    // `BENCH_sweep.json`'s `wall.sim_throughput_rps` tracks and
    // `scripts/check_bench_regression.py` gates.
    let kind = GpuKind::V100;
    let (hw, wls) = igniter::profiler::profile_all(kind, 42);
    let sys = ProfiledSystem {
        hw,
        coeffs: igniter::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
    };
    let specs = app_workloads();
    let plan = provisioner::provision(&sys, &specs);
    let epochs = 12;
    let epoch_ms = 2_500.0;
    let trace = RateTrace::generate(
        TraceKind::Diurnal {
            period_epochs: epochs,
            floor: 0.35,
        },
        epochs,
        specs.len(),
        42,
    );
    let (served, ns) = bench_once("sim core closed loop 12wl x 30s diurnal", || {
        let mut sim = ClusterSim::new(
            kind,
            &plan,
            &specs,
            Policy::Static,
            ArrivalKind::Constant,
            42,
            &[],
        );
        sim.set_serving_policy(Box::new(Reprovisioner::new(
            sys.clone(),
            specs.clone(),
            plan.clone(),
        )));
        sim.set_rate_trace(&trace, epoch_ms);
        sim.set_horizon(epochs as f64 * epoch_ms, 1_000.0);
        sim.run().iter().map(|s| s.served).sum::<u64>()
    });
    println!(
        "  -> sim_throughput_rps: {:.0} ({served} served requests)",
        served as f64 / (ns / 1e9)
    );

    // The same closed loop with the chaos layer live: a sampled fault
    // plan, breakers, shed/hedge routing, and failover respecs.  The
    // interesting number is the overhead relative to the fault-free run
    // above — the chaos machinery must cost noise, not throughput.
    let horizon = epochs as f64 * epoch_ms;
    let fplan = FaultPlan::generate(&FaultSpace::chaos(), 42, 0, horizon);
    let (served_chaos, ns_chaos) = bench_once("sim core chaos 12wl x 30s diurnal", || {
        let mut sim = ClusterSim::new(
            kind,
            &plan,
            &specs,
            Policy::Static,
            ArrivalKind::Constant,
            42,
            &[],
        );
        sim.set_serving_policy(Box::new(
            Reprovisioner::new(sys.clone(), specs.clone(), plan.clone())
                .with_resilience(Resilience::ALL),
        ));
        sim.set_fault_plan(fplan.clone());
        sim.set_rate_trace(&trace, epoch_ms);
        sim.set_horizon(horizon, 1_000.0);
        sim.run().iter().map(|s| s.served).sum::<u64>()
    });
    println!(
        "  -> chaos sim_throughput_rps: {:.0} ({served_chaos} served, {} fault event(s), {:+.1}% wall vs fault-free)",
        served_chaos as f64 / (ns_chaos / 1e9),
        fplan.len(),
        (ns_chaos / ns - 1.0) * 100.0
    );

    // Long-tail closed loop: mostly-idle tenant populations at 100/500/
    // 1000, served twice — idle-aware fast path vs. the reference full
    // walk (`set_idle_fast_path(false)`).  The ratio is the number the
    // tentpole claims: per-tick monitor cost proportional to activity,
    // not tenancy, at bitwise-identical results (pinned by the forall
    // property in `coordinator/server.rs`).
    println!("\n== long-tail closed loop (fast path vs reference walk) ==");
    for &tenants in &[100usize, 500, 1000] {
        let specs: Vec<igniter::provisioner::WorkloadSpec> = (0..tenants)
            .map(|i| {
                let model = igniter::gpu::ALL_MODELS[i % igniter::gpu::ALL_MODELS.len()];
                let (slo_lo, slo_hi, _rate_lo, rate_hi) = igniter::workload::envelope(model);
                // one heavy hitter per ten tenants; the rest near-idle
                let rate = if i % 10 == 0 { (rate_hi * 0.5).max(1.0) } else { 0.5 };
                igniter::provisioner::WorkloadSpec::new(i, model, 0.5 * (slo_lo + slo_hi), rate)
            })
            .collect();
        let lt_plan = provisioner::provision(&sys, &specs);
        let lt_epochs = 4;
        let lt_epoch_ms = 1_500.0;
        let lt_trace = RateTrace::generate(
            TraceKind::Diurnal {
                period_epochs: lt_epochs,
                floor: 0.35,
            },
            lt_epochs,
            specs.len(),
            42,
        );
        let mut run = |fast: bool, label: &str| {
            bench_once(label, || {
                let mut sim = ClusterSim::new(
                    kind,
                    &lt_plan,
                    &specs,
                    Policy::Static,
                    ArrivalKind::Poisson,
                    42,
                    &[],
                );
                sim.set_idle_fast_path(fast);
                sim.set_serving_policy(Box::new(Reprovisioner::new(
                    sys.clone(),
                    specs.clone(),
                    lt_plan.clone(),
                )));
                sim.set_rate_trace(&lt_trace, lt_epoch_ms);
                sim.set_horizon(lt_epochs as f64 * lt_epoch_ms, 500.0);
                sim.run().iter().map(|s| s.served).sum::<u64>()
            })
        };
        let (served_fast, ns_fast) = run(true, &format!("longtail {tenants} tenants, fast path"));
        let (served_ref, ns_ref) = run(false, &format!("longtail {tenants} tenants, full walk"));
        assert_eq!(served_fast, served_ref, "fast path changed serving");
        println!(
            "  -> {tenants} tenants: sim_throughput_rps {:.0} fast / {:.0} walk ({:.1}x)",
            served_fast as f64 / (ns_fast / 1e9),
            served_ref as f64 / (ns_ref / 1e9),
            ns_ref / ns_fast
        );
    }
}
