//! Bench: fleet-scale sweep throughput — the numbers behind the CI
//! `bench-sweep` gate.  Reports (a) single closed-loop scenario latency,
//! (b) sequential vs parallel sweep wall-clock over the same task set
//! (the speedup is the whole point of the scoped-worker fan-out), and
//! (c) served virtual requests per wall second, the sim-throughput
//! metric `BENCH_sweep.json` tracks run-over-run.

use igniter::sweep::{profiled_pair, run_sweep, run_task, ScenarioSpace, SweepConfig};
use igniter::util::bench::{bench, bench_once};

fn cfg(parallel: usize, scenarios: usize) -> SweepConfig {
    SweepConfig {
        scenarios,
        seeds: 1,
        parallel,
        master_seed: 42,
        space: ScenarioSpace::quick(),
    }
}

fn main() {
    println!("== sweep benches ==");

    // Single-task latency: provision + closed-loop serve of one quick
    // scenario (the unit of work the fan-out schedules).
    let systems = profiled_pair(42);
    let one = cfg(1, 1);
    bench("sweep_task quick scenario (provision+serve)", 1, 5, || {
        let r = run_task(&one, &systems, 0);
        assert!(r.feasible && r.dropped == 0);
        r.served
    });

    // Sequential vs parallel over an identical 32-task set.  The merged
    // results are bit-identical (tests/sweep_determinism.rs proves it);
    // here we measure the wall-clock ratio.
    let (seq, seq_ns) = bench_once("sweep 32 scenarios sequential", || {
        run_sweep(&cfg(1, 32))
    });
    let (par, par_ns) = bench_once("sweep 32 scenarios parallel x8", || {
        run_sweep(&cfg(8, 32))
    });
    assert_eq!(
        seq.fingerprint(),
        par.fingerprint(),
        "parallel sweep diverged from sequential"
    );
    let agg = par.aggregate();
    println!(
        "  -> speedup {:.2}x  ({} tasks, {} served; {:.0} served req/s of wall at x8)",
        seq_ns / par_ns.max(1.0),
        agg.tasks,
        agg.total_served,
        agg.total_served as f64 / (par_ns / 1e9).max(1e-9),
    );
}
