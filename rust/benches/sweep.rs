//! Bench: fleet-scale sweep throughput — the numbers behind the CI
//! `bench-sweep` gate.  Reports (a) the placement-scoring microbench
//! (incremental `DeviceScorer` vs the old rebuild-per-candidate pattern
//! — the O(1)-per-candidate claim as a measured ratio), (b) single
//! closed-loop scenario latency, (c) sequential vs parallel sweep
//! wall-clock over the same task set (the speedup is the whole point of
//! the scoped-worker fan-out), and (d) served virtual requests per wall
//! second, the sim-throughput metric `BENCH_sweep.json` tracks
//! run-over-run.

use igniter::perfmodel::{self, DeviceScorer, PlacedWorkload};
use igniter::sweep::{profiled_pair, run_sweep, run_task, ScenarioSpace, SweepConfig};
use igniter::util::bench::{bench, bench_once};

fn cfg(parallel: usize, scenarios: usize) -> SweepConfig {
    SweepConfig {
        scenarios,
        seeds: 1,
        parallel,
        master_seed: 42,
        space: ScenarioSpace::quick(),
        calibrate: false,
    }
}

fn main() {
    println!("== sweep benches ==");

    // Placement-scoring microbench: Alg. 2's inner loop evaluates every
    // resident of a device each growth pass.  The old pattern rebuilt
    // the placed view and re-summed the aggregates per candidate (O(m)
    // coefficient-law evaluations each); the DeviceScorer answers each
    // candidate in O(1) from cached per-slot contributions.  Both sides
    // here do 8 passes x m candidates over an m-resident device, with a
    // resize between passes (the growth step), and must agree bitwise.
    let systems = profiled_pair(42);
    let hw = &systems[0].hw;
    let coeffs: Vec<_> = systems[0].coeffs.iter().map(|(_, wc)| wc).collect();
    let m = 8usize;
    let base: Vec<PlacedWorkload> = (0..m)
        .map(|i| PlacedWorkload {
            coeffs: coeffs[i % coeffs.len()],
            batch: 4.0 + (i % 4) as f64 * 4.0,
            resources: 0.1,
        })
        .collect();
    let passes = 8usize;
    let inc = bench("placement scoring: DeviceScorer (incremental)", 50, 400, || {
        let mut scorer = DeviceScorer::from_placed(hw, base.iter().cloned());
        let mut acc = 0.0;
        for pass in 0..passes {
            for i in 0..m {
                acc += scorer.predict(i).t_inf;
            }
            let grow = pass % m;
            let r = scorer.placed(grow).resources + hw.r_unit;
            scorer.set_resources(grow, r);
        }
        acc
    });
    let rebuild = bench("placement scoring: rebuild per candidate (old)", 50, 400, || {
        let mut placed = base.clone();
        let mut acc = 0.0;
        for pass in 0..passes {
            for i in 0..m {
                // the pre-refactor shape: a fresh Vec + full re-sum per
                // candidate prediction
                let view: Vec<PlacedWorkload> = placed.to_vec();
                acc += perfmodel::predict(hw, &view, i).t_inf;
            }
            let grow = pass % m;
            placed[grow].resources += hw.r_unit;
        }
        acc
    });
    println!(
        "  -> scorer speedup {:.2}x per candidate-scan",
        rebuild.mean_ns / inc.mean_ns.max(1.0)
    );
    // equality of the two paths (bitwise) is property-tested in
    // perfmodel::scorer; here we just sanity-check the workload agreed
    {
        let scorer = DeviceScorer::from_placed(hw, base.iter().cloned());
        for i in 0..m {
            assert_eq!(
                scorer.predict(i).t_inf.to_bits(),
                perfmodel::predict(hw, &base, i).t_inf.to_bits()
            );
        }
    }

    // Single-task latency: provision + closed-loop serve of one quick
    // scenario (the unit of work the fan-out schedules).
    let one = cfg(1, 1);
    bench("sweep_task quick scenario (provision+serve)", 1, 5, || {
        let r = run_task(&one, &systems, 0);
        assert!(r.feasible && r.dropped == 0);
        r.served
    });

    // Sequential vs parallel over an identical 32-task set.  The merged
    // results are bit-identical (tests/sweep_determinism.rs proves it);
    // here we measure the wall-clock ratio.
    let (seq, seq_ns) = bench_once("sweep 32 scenarios sequential", || {
        run_sweep(&cfg(1, 32))
    });
    let (par, par_ns) = bench_once("sweep 32 scenarios parallel x8", || {
        run_sweep(&cfg(8, 32))
    });
    assert_eq!(
        seq.fingerprint(),
        par.fingerprint(),
        "parallel sweep diverged from sequential"
    );
    let agg = par.aggregate();
    println!(
        "  -> speedup {:.2}x  ({} tasks, {} served; {:.0} served req/s of wall at x8)",
        seq_ns / par_ns.max(1.0),
        agg.tasks,
        agg.total_served,
        agg.total_served as f64 / (par_ns / 1e9).max(1e-9),
    );
}
