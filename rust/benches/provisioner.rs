//! Bench: the fleet-scale placement engine vs the retained linear scan.
//!
//! Alg. 1 placement at 50/200/1000 workloads, both through the indexed
//! `PlacementEngine` (headroom buckets + persistent per-device scorers +
//! admissible pruning — the default `provision_with` path) and through
//! the retained exhaustive reference (`provision_with_linear`).  The two
//! must produce bit-identical plans — asserted here before timing, so a
//! bench run that would publish numbers for divergent plans aborts.
//!
//! Prints `plan_throughput_pps` (placement items per wall-second) for
//! each side — the same work unit `wall.plan_throughput_pps` counts in
//! `BENCH_sweep.json`, measured here on the pure offline pass.

use igniter::gpu::GpuKind;
use igniter::perfmodel::AnalyticModel;
use igniter::provisioner::{igniter as ig, ProfiledSystem};
use igniter::util::bench::bench;
use igniter::workload::synthetic_workloads;

fn sys() -> ProfiledSystem {
    let (hw, wls) = igniter::profiler::profile_all(GpuKind::V100, 42);
    ProfiledSystem {
        hw,
        coeffs: igniter::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
    }
}

fn main() {
    println!("== placement-engine benches (indexed vs linear scan) ==");
    let s = sys();

    for &m in &[50usize, 200, 1000] {
        let specs = synthetic_workloads(m, 42);

        let indexed = ig::provision_with(&AnalyticModel::ALL, &s, &specs);
        let linear = ig::provision_with_linear(&AnalyticModel::ALL, &s, &specs);
        assert_eq!(
            indexed, linear,
            "engine diverged from the linear reference at m={m}"
        );
        let placements = indexed.total_allocs();

        // the linear scan is ~quadratic in fleet size — keep its
        // iteration count down at the top end
        let (warmup, iters) = if m <= 200 { (2, 20) } else { (1, 3) };
        let lin = bench(&format!("place_linear(m={m})"), warmup, iters, || {
            ig::provision_with_linear(&AnalyticModel::ALL, &s, &specs)
        });
        let idx = bench(&format!("place_indexed(m={m})"), warmup, iters, || {
            ig::provision_with(&AnalyticModel::ALL, &s, &specs)
        });
        let pps = |mean_ns: f64| placements as f64 / (mean_ns / 1e9).max(1e-12);
        println!(
            "  m={m}: {placements} placements | plan_throughput_pps linear {:.0} | indexed {:.0} | speedup {:.2}x",
            pps(lin.mean_ns),
            pps(idx.mean_ns),
            lin.mean_ns / idx.mean_ns.max(1e-12),
        );
    }
}
