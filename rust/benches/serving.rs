//! Bench: end-to-end serving — the Fig.-14 virtual-time simulation (one
//! run per strategy) and the real PJRT execution path per (model, batch)
//! variant (the wall-clock compute cost behind EXPERIMENTS.md §Perf L3).

use igniter::coordinator::{ClusterSim, Policy, Reprovisioner};
use igniter::gpu::GpuKind;
use igniter::provisioner::{self, ProfiledSystem};
use igniter::runtime::{Engine, Manifest};
use igniter::util::bench::{bench, bench_once};
use igniter::workload::trace::{RateTrace, TraceKind};
use igniter::workload::{app_workloads, ArrivalKind};
use std::path::Path;

fn main() {
    println!("== serving benches ==");
    let kind = GpuKind::V100;
    let (hw, wls) = igniter::profiler::profile_all(kind, 42);
    let sys = ProfiledSystem {
        hw,
        coeffs: igniter::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
    };
    let specs = app_workloads();
    let plan = provisioner::provision(&sys, &specs);

    bench("cluster_sim 12wl x 10s virtual", 1, 10, || {
        let mut sim = ClusterSim::new(
            kind,
            &plan,
            &specs,
            Policy::IgniterShadow,
            ArrivalKind::Constant,
            42,
            &[],
        );
        sim.set_horizon(10_000.0, 1_000.0);
        sim.run().len()
    });

    // Long horizon: 120 s of virtual time serves ~12x the requests of the
    // 10 s run.  With the sliding-window monitor the per-tick cost is
    // O(window), so the mean here should scale ~linearly with the horizon
    // (~12x the run above), not quadratically as the old rescan-everything
    // monitor did.  Compare ns/served-request across the two lines.
    let mut served_120s = 0u64;
    let long = bench("cluster_sim 12wl x 120s virtual", 0, 3, || {
        let mut sim = ClusterSim::new(
            kind,
            &plan,
            &specs,
            Policy::IgniterShadow,
            ArrivalKind::Constant,
            42,
            &[],
        );
        sim.set_horizon(120_000.0, 1_000.0);
        served_120s = sim.run().iter().map(|s| s.served).sum::<u64>();
        served_120s
    });
    println!(
        "  -> {:.0} ns per served request over {} requests (flat vs. horizon = monitor is O(window))",
        long.mean_ns / served_120s.max(1) as f64,
        served_120s
    );

    // Closed loop: estimator + online re-plans + shadow migrations on a
    // live 60 s diurnal trace.  The overhead vs the static 120 s line
    // above is the price of re-provisioning (per-tick EWMA + occasional
    // Alg.-1 incremental placements) — it should stay a small multiple.
    let epochs = 24;
    let epoch_ms = 2_500.0;
    let trace = RateTrace::generate(
        TraceKind::Diurnal {
            period_epochs: epochs,
            floor: 0.35,
        },
        epochs,
        specs.len(),
        42,
    );
    bench("autoscale closed loop 12wl x 60s diurnal", 0, 3, || {
        let mut sim = ClusterSim::new(
            kind,
            &plan,
            &specs,
            Policy::Static,
            ArrivalKind::Constant,
            42,
            &[],
        );
        sim.set_serving_policy(Box::new(Reprovisioner::new(
            sys.clone(),
            specs.clone(),
            plan.clone(),
        )));
        sim.set_rate_trace(&trace, epoch_ms);
        sim.set_horizon(epochs as f64 * epoch_ms, 1_000.0);
        let served: u64 = sim.run().iter().map(|s| s.served).sum();
        (served, sim.migrations())
    });

    // Real PJRT path (skipped when artifacts are absent or the runtime
    // is the offline stub).
    if !igniter::runtime::PJRT_AVAILABLE {
        println!("(PJRT runtime stubbed — skipping real-compute benches)");
        return;
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("(artifacts not built — skipping real-compute benches)");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let mut engine = Engine::new(manifest).unwrap();
    let (_, compile_ns) = bench_once("compile all 24 hlo variants", || {
        engine.load_all(None).unwrap();
        engine.loaded_count()
    });
    let _ = compile_ns;

    for model in ["alexnet", "resnet50", "vgg19", "ssd"] {
        for b in [1usize, 8, 32] {
            let lv = engine.variant(model, b).unwrap();
            let input = vec![0.5f32; lv.variant.input_len()];
            bench(&format!("pjrt_execute {model} b={b}"), 2, 15, || {
                lv.execute(&input).unwrap().len()
            });
        }
    }
}
