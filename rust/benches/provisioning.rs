//! Bench: provisioning-strategy hot paths (paper Fig. 21 / Sec. 5.4).
//!
//! Regenerates the paper's algorithm-overhead claims: Alg. 1 at m = 12
//! must be in the low milliseconds; at m = 1000 it must stay within
//! seconds with ~quadratic scaling.  Also microbenches Alg. 2
//! (`alloc_gpus`) and the Eq.-17/18 closed forms.

use igniter::gpu::GpuKind;
use igniter::perfmodel::AnalyticModel;
use igniter::provisioner::{ffd, gpulets, gslice, igniter as ig, ProfiledSystem};
use igniter::util::bench::{bench, bench_once};
use igniter::workload::{app_workloads, synthetic_workloads};

fn sys() -> ProfiledSystem {
    let (hw, wls) = igniter::profiler::profile_all(GpuKind::V100, 42);
    ProfiledSystem {
        hw,
        coeffs: igniter::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
    }
}

fn main() {
    println!("== provisioning benches (paper Fig. 21 / Sec. 5.4) ==");
    let s = sys();
    let specs12 = app_workloads();

    bench("eq17_eq18_derive_all(m=12)", 20, 200, || {
        ig::derive_all(&s, &specs12)
    });

    let derived = ig::derive_all(&s, &specs12);
    let d0 = derived[11].unwrap(); // SSD App3, the heavy one
    let resident: Vec<igniter::provisioner::Alloc> = vec![igniter::provisioner::Alloc {
        workload: 1,
        resources: derived[1].unwrap().r_lower,
        batch: derived[1].unwrap().batch,
    }];
    bench("alloc_gpus(alg2, 1 resident)", 20, 200, || {
        ig::alloc_gpus(&AnalyticModel::ALL, &s, &specs12, &resident, 11, d0.r_lower, d0.batch)
    });

    bench("igniter_provision(m=12)  [paper: 3.64 ms]", 5, 50, || {
        ig::provision(&s, &specs12)
    });
    bench("ffd_provision(m=12)", 5, 50, || {
        ffd::provision_ffd(&s, &specs12)
    });
    bench("gpulets_provision(m=12)", 5, 50, || {
        gpulets::provision_gpulets(&s, &specs12)
    });
    bench_once("gslice_provision(m=12)", || {
        gslice::provision_gslice(&s, &specs12)
    });

    for &m in &[100usize, 500, 1000] {
        let specs = synthetic_workloads(m, 42);
        let iters = if m <= 100 { 20 } else { 5 };
        bench(
            &format!("igniter_provision(m={m})  [paper @1000: <=4.61 s]"),
            1,
            iters,
            || ig::provision(&s, &specs),
        );
    }
}
